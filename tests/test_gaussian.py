"""Hypothesis property tests on the Gaussian-product algebra (paper Eqs 3.1/3.2)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, hnp, st

from repro.core.gaussian import (
    fit_moments,
    log_isotropic_normal_pdf,
    log_normal_pdf,
    product_moments,
    product_moments_diag,
    sample_gaussian,
)


def _spd(key, d, scale=1.0):
    a = jax.random.normal(key, (d, d))
    return scale * (a @ a.T / d + jnp.eye(d))


@given(st.integers(1, 5), st.integers(1, 6), st.integers(0, 10_000))
def test_product_moments_matches_bruteforce(m, d, seed):
    key = jax.random.PRNGKey(seed)
    means = jax.random.normal(key, (m, d))
    covs = jnp.stack([_spd(jax.random.fold_in(key, i), d) for i in range(m)])
    got = product_moments(means, covs)
    precs = np.stack([np.linalg.inv(np.asarray(c)) for c in covs])
    lam = precs.sum(0)
    cov = np.linalg.inv(lam)
    mean = cov @ np.einsum("mij,mj->i", precs, np.asarray(means))
    np.testing.assert_allclose(got.cov, cov, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(got.mean, mean, rtol=2e-3, atol=2e-4)


@given(st.integers(1, 6), st.integers(1, 50), st.integers(0, 10_000))
def test_product_diag_matches_full_on_diagonal_inputs(m, d, seed):
    key = jax.random.PRNGKey(seed)
    means = jax.random.normal(key, (m, d))
    variances = jax.random.uniform(jax.random.fold_in(key, 1), (m, d), minval=0.1, maxval=3.0)
    diag = product_moments_diag(means, variances)
    full = product_moments(means, jax.vmap(jnp.diag)(variances))
    np.testing.assert_allclose(diag.mean, full.mean, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(jnp.diag(full.cov), diag.cov, rtol=1e-4, atol=1e-5)


@given(st.integers(1, 4), st.integers(0, 1000))
def test_product_with_single_factor_is_identity(d, seed):
    key = jax.random.PRNGKey(seed)
    mean = jax.random.normal(key, (1, d))
    cov = _spd(jax.random.fold_in(key, 1), d)[None]
    got = product_moments(mean, cov)
    np.testing.assert_allclose(got.mean, mean[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got.cov, cov[0], rtol=1e-4, atol=1e-5)


@given(st.integers(2, 5), st.integers(0, 1000))
def test_product_commutative(m, seed):
    key = jax.random.PRNGKey(seed)
    d = 3
    means = jax.random.normal(key, (m, d))
    covs = jnp.stack([_spd(jax.random.fold_in(key, i), d) for i in range(m)])
    perm = jax.random.permutation(jax.random.fold_in(key, 99), m)
    a = product_moments(means, covs)
    b = product_moments(means[perm], covs[perm])
    np.testing.assert_allclose(a.mean, b.mean, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a.cov, b.cov, rtol=1e-4, atol=1e-5)


def test_log_normal_pdf_matches_scipy_formula():
    key = jax.random.PRNGKey(0)
    d = 4
    x = jax.random.normal(key, (7, d))
    mean = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    cov = _spd(jax.random.fold_in(key, 2), d)
    got = log_normal_pdf(x, mean, cov)
    diff = np.asarray(x - mean)
    c = np.asarray(cov)
    want = (
        -0.5 * np.einsum("bi,ij,bj->b", diff, np.linalg.inv(c), diff)
        - 0.5 * np.linalg.slogdet(c)[1]
        - 0.5 * d * np.log(2 * np.pi)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # isotropic special case agrees
    got_iso = log_isotropic_normal_pdf(x, mean, 0.7)
    want_iso = log_normal_pdf(x, mean, 0.7 * jnp.eye(d))
    np.testing.assert_allclose(got_iso, want_iso, rtol=1e-5, atol=1e-5)


def test_fit_moments_masked_equals_fit_on_subset():
    key = jax.random.PRNGKey(1)
    s = jax.random.normal(key, (50, 3)) * 2.0 + 1.0
    mask = jnp.array([1.0] * 30 + [0.0] * 20)
    a = fit_moments(s, mask)
    b = fit_moments(s[:30])
    np.testing.assert_allclose(a.mean, b.mean, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a.cov, b.cov, rtol=1e-4, atol=1e-5)


def test_sample_gaussian_moments_converge():
    key = jax.random.PRNGKey(2)
    d = 3
    mean = jnp.array([1.0, -2.0, 0.5])
    cov = _spd(key, d)
    from repro.core.gaussian import GaussianMoments

    draws = sample_gaussian(jax.random.fold_in(key, 1), GaussianMoments(mean, cov), 200_000)
    np.testing.assert_allclose(draws.mean(0), mean, atol=2e-2)
    emp = np.cov(np.asarray(draws).T)
    np.testing.assert_allclose(emp, cov, atol=5e-2)
