"""Hypothesis properties of the combiners themselves (machine symmetry,
affine equivariance, ragged-count degeneracies)."""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from repro.core import combine


def _samples(seed, m, t, d, spread=1.0):
    key = jax.random.PRNGKey(seed)
    centers = spread * jax.random.normal(key, (m, 1, d))
    return centers + 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (m, t, d))


@given(st.integers(2, 6), st.integers(0, 500))
def test_parametric_machine_permutation_invariance(m, seed):
    s = _samples(seed, m, 200, 3)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), m)
    a = combine.parametric(jax.random.PRNGKey(0), s, 10)
    b = combine.parametric(jax.random.PRNGKey(0), s[perm], 10)
    np.testing.assert_allclose(a.moments.mean, b.moments.mean, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a.moments.cov, b.moments.cov, rtol=1e-3, atol=1e-5)


@given(st.integers(0, 300))
def test_parametric_translation_equivariance(seed):
    """Shifting every machine's samples by c shifts the product mean by c."""
    s = _samples(seed, 4, 150, 2)
    c = jnp.asarray([2.5, -1.0])
    a = combine.parametric(jax.random.PRNGKey(0), s, 10)
    b = combine.parametric(jax.random.PRNGKey(0), s + c, 10)
    np.testing.assert_allclose(b.moments.mean, a.moments.mean + c, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b.moments.cov, a.moments.cov, rtol=1e-4, atol=1e-6)


@given(st.integers(0, 300))
def test_single_machine_combination_is_identityish(seed):
    """M=1: the product of one subposterior is that subposterior — the
    parametric combiner must return its moments unchanged."""
    s = _samples(seed, 1, 400, 3)
    res = combine.parametric(jax.random.PRNGKey(0), s, 50)
    np.testing.assert_allclose(res.moments.mean, s[0].mean(0), rtol=1e-4, atol=1e-5)


@given(st.integers(0, 200))
def test_img_weight_shift_invariance(seed):
    """w_t depends only on spread around θ̄ — shifting all selected samples
    leaves the weight unchanged (Eq 3.5)."""
    key = jax.random.PRNGKey(seed)
    theta = jax.random.normal(key, (6, 4))
    h = jnp.asarray(0.7)
    a = combine.log_weight_bruteforce(theta, h)
    b = combine.log_weight_bruteforce(theta + 3.3, h)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@given(st.integers(2, 5), st.integers(0, 200))
def test_counts_full_equals_none(m, seed):
    """counts=T must be exactly equivalent to counts=None everywhere."""
    s = _samples(seed, m, 64, 2)
    counts = jnp.full((m,), 64, jnp.int32)
    a = combine.subpost_average(s)
    b = combine.subpost_average(s, counts=counts)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pa = combine.parametric(jax.random.PRNGKey(1), s, 16)
    pb = combine.parametric(jax.random.PRNGKey(1), s, 16, counts=counts)
    np.testing.assert_allclose(pa.samples, pb.samples, rtol=1e-5, atol=1e-6)
