"""Batched all-machines KDE scoring op: kernel-vs-ref parity + masking laws.

Covers the PR-8 contracts:
- the Pallas kernel (interpret=True) matches the chunked jnp ref on dense and
  ragged inputs;
- the dense path matches the historical per-machine loop over the
  single-machine ``kde_log_density`` kernel;
- the ragged ref is bitwise-identical to the pre-batching
  ``machine_kde_logpdfs`` masked-logsumexp implementation;
- NaN garbage in rows beyond ``counts[m]`` is provably inert;
- fused ``product`` / ``mixture`` epilogues equal the explicit reductions of
  the (M, Q) matrix;
- ``masked_silverman``'s bandwidth floor keeps constant chains finite.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.combiners.density import machine_kde_logpdfs, masked_silverman
from repro.kernels.kde_density import (
    kde_log_density,
    machine_kde_log_density,
    machine_kde_log_density_ref,
)


def _case(seed, M, T, d, Q, ragged):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    samples = jax.random.normal(ks[0], (M, T, d), jnp.float32)
    queries = jax.random.normal(ks[1], (Q, d), jnp.float32)
    h = jnp.abs(jax.random.normal(ks[2], (M,))) * 0.4 + 0.2
    if ragged:
        counts = jax.random.randint(ks[3], (M,), 1, T + 1).astype(jnp.int32)
        counts = counts.at[0].set(T)  # keep one dense machine in the mix
    else:
        counts = None
    return queries, samples, h, counts


def _allclose_lp(got, want, **kw):
    """allclose over log densities where both −inf (empty machines) agree."""
    got, want = np.asarray(got), np.asarray(want)
    inf = np.isneginf(got) & np.isneginf(want)
    assert not np.any(np.isnan(got))
    np.testing.assert_allclose(np.where(inf, 0.0, got), np.where(inf, 0.0, want), **kw)


@pytest.mark.parametrize("M,T,d,Q", [(5, 700, 7, 300), (3, 512, 50, 256), (8, 130, 2, 65), (2, 64, 1, 64)])
@pytest.mark.parametrize("ragged", [False, True])
def test_kernel_matches_ref(M, T, d, Q, ragged):
    queries, samples, h, counts = _case(M * T + Q, M, T, d, Q, ragged)
    got = machine_kde_log_density(
        queries, samples, h, counts, impl="kernel", interpret=True
    )
    want = machine_kde_log_density_ref(queries, samples, h, counts)
    assert got.shape == (M, Q)
    _allclose_lp(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_dense_matches_per_machine_loop(impl):
    """The batched op ≡ the historical M-launch loop on dense chains."""
    queries, samples, h, _ = _case(11, 6, 400, 10, 200, ragged=False)
    got = machine_kde_log_density(
        queries, samples, h, None, impl=impl, interpret=True
    )
    want = jnp.stack(
        [kde_log_density(queries, samples[m], h[m]) for m in range(6)]
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_ragged_ref_bitwise_matches_historical_masked_path():
    """ref ≡ the pre-batching chunked masked-logsumexp, bit for bit."""
    queries, samples, h, counts = _case(23, 5, 300, 8, 270, ragged=True)

    # the exact pre-PR8 machine_kde_logpdfs ragged implementation
    M, T, d = samples.shape
    chunk = 256
    mask = jnp.arange(T)[None, :] < counts[:, None]
    csq = jnp.sum(samples**2, axis=-1)
    Q = queries.shape[0]
    pad = (-Q) % chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0))).reshape(-1, chunk, d)

    def block(qc):
        sq = (
            jnp.sum(qc**2, axis=-1)[None, :, None]
            + csq[:, None, :]
            - 2.0 * jnp.einsum("qd,mtd->mqt", qc, samples)
        )
        logk = -0.5 * sq / (h[:, None, None] ** 2)
        logk = jnp.where(mask[:, None, :], logk, -jnp.inf)
        return jax.scipy.special.logsumexp(logk, axis=-1)

    out = jax.lax.map(block, qp)
    lse = jnp.moveaxis(out, 0, 1).reshape(M, -1)[:, :Q]
    log_norm = (
        -jnp.log(jnp.maximum(counts.astype(queries.dtype), 1.0))
        - 0.5 * d * (2.0 * jnp.log(h) + math.log(2.0 * math.pi))
    )
    want = lse + log_norm[:, None]

    got = machine_kde_log_density_ref(queries, samples, h, counts)
    assert bool(jnp.all(got == want))
    # and the density.py helper routes ragged calls through the same ref
    via_helper = machine_kde_logpdfs(queries, samples, counts, h)
    assert bool(jnp.all(via_helper == want))


@pytest.mark.parametrize("impl", ["ref", "kernel"])
def test_nan_garbage_beyond_counts_is_inert(impl):
    """Scores with NaN-poisoned invalid rows ≡ scores with clean rows."""
    queries, samples, h, counts = _case(37, 5, 400, 6, 200, ragged=True)
    counts = counts.at[2].set(0)  # empty machine: all rows garbage
    tidx = jnp.arange(samples.shape[1])[None, :, None]
    poisoned = jnp.where(tidx < counts[:, None, None], samples, jnp.nan)

    clean = machine_kde_log_density(
        queries, samples, h, counts, impl=impl, interpret=True
    )
    dirty = machine_kde_log_density(
        queries, poisoned, h, counts, impl=impl, interpret=True
    )
    assert not bool(jnp.any(jnp.isnan(dirty)))
    inf = jnp.isneginf(clean) & jnp.isneginf(dirty)
    assert bool(jnp.all(inf | (clean == dirty)))
    # the empty machine scores −inf everywhere (its KDE has no support)
    assert bool(jnp.all(jnp.isneginf(dirty[2])))


@pytest.mark.parametrize("impl", ["ref", "kernel"])
@pytest.mark.parametrize("weights", ["uniform", "counts"])
def test_fused_reductions_match_explicit(impl, weights):
    queries, samples, h, counts = _case(53, 6, 500, 5, 300, ragged=True)
    full = machine_kde_log_density(
        queries, samples, h, counts, impl=impl, interpret=True
    )
    prod, mix = machine_kde_log_density(
        queries, samples, h, counts,
        reduce="product_mixture", mixture_weights=weights,
        impl=impl, interpret=True,
    )
    prod_only = machine_kde_log_density(
        queries, samples, h, counts, reduce="product", impl=impl, interpret=True
    )
    mix_only = machine_kde_log_density(
        queries, samples, h, counts,
        reduce="mixture", mixture_weights=weights, impl=impl, interpret=True,
    )
    M = samples.shape[0]
    want_prod = jnp.sum(full, axis=0)
    if weights == "uniform":
        want_mix = jax.scipy.special.logsumexp(full, axis=0) - jnp.log(float(M))
    else:
        cf = counts.astype(full.dtype)
        logw = jnp.log(cf) - jnp.log(jnp.sum(cf))
        want_mix = jax.scipy.special.logsumexp(full + logw[:, None], axis=0)
    _allclose_lp(prod, want_prod, rtol=1e-5, atol=1e-4)
    _allclose_lp(prod_only, want_prod, rtol=1e-5, atol=1e-4)
    _allclose_lp(mix, want_mix, rtol=1e-5, atol=1e-4)
    _allclose_lp(mix_only, want_mix, rtol=1e-5, atol=1e-4)


def test_fused_uniform_mixture_bitwise_matches_importance_pool_form():
    """ref ``mixture_weights="uniform"`` ≡ logsumexp(logp, 0) − log M exactly
    (the historical importance_pool proposal reduction)."""
    queries, samples, h, counts = _case(71, 4, 300, 3, 200, ragged=True)
    full = machine_kde_log_density_ref(queries, samples, h, counts)
    mix = machine_kde_log_density_ref(
        queries, samples, h, counts, reduce="mixture", mixture_weights="uniform"
    )
    want = jax.scipy.special.logsumexp(full, axis=0) - jnp.log(
        jnp.asarray(4, full.dtype)
    )
    assert bool(jnp.all(mix == want))


def test_vmap_over_pairs():
    """The tree-reduction usage: vmap the helper over stacked machine pairs."""
    queries, samples, h, counts = _case(89, 6, 200, 4, 100, ragged=True)
    pairs = samples.reshape(3, 2, 200, 4)
    pair_counts = counts.reshape(3, 2)
    pair_h = h.reshape(3, 2)
    got = jax.vmap(
        lambda s, c, hh: machine_kde_logpdfs(queries, s, c, hh)
    )(pairs, pair_counts, pair_h)
    for p in range(3):
        want = machine_kde_logpdfs(queries, pairs[p], pair_counts[p], pair_h[p])
        _allclose_lp(got[p], want, rtol=1e-6, atol=1e-6)


def test_masked_silverman_floor_keeps_constant_chain_finite():
    """A constant chain has σ=0; the 1e-8 bandwidth floor must keep its own
    scores finite instead of NaN-poisoning the pooled logits."""
    M, T, d = 3, 50, 4
    samples = jax.random.normal(jax.random.PRNGKey(0), (M, T, d), jnp.float32)
    samples = samples.at[1].set(1.5)  # machine 1: every draw identical
    counts = jnp.full((M,), T, jnp.int32)
    h = masked_silverman(samples, counts)
    assert bool(jnp.all(h >= 1e-8))
    # scoring the constant chain's own location stays finite for machine 1
    q = jnp.concatenate([jnp.full((1, d), 1.5), samples[0, :4]])
    logp = machine_kde_log_density(q, samples, h, counts)
    assert bool(jnp.isfinite(logp[1, 0]))
    assert not bool(jnp.any(jnp.isnan(logp)))
    # single-draw chains hit the same floor path
    h1 = masked_silverman(samples, jnp.array([1, 1, 1], jnp.int32))
    assert bool(jnp.all(h1 >= 1e-8)) and not bool(jnp.any(jnp.isnan(h1)))
