"""Pallas flash-attention kernel (interpret mode) vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


@pytest.mark.parametrize(
    "b,s,t,kh,g,hd,hdv,causal",
    [
        (1, 128, 128, 1, 1, 32, 32, True),
        (2, 128, 128, 2, 2, 32, 16, True),  # GQA + MLA-style hd_v != hd
        (1, 100, 160, 1, 4, 16, 16, False),  # ragged + cross lengths
        (1, 256, 256, 2, 1, 64, 64, True),
    ],
)
def test_pallas_flash_matches_ref(b, s, t, kh, g, hd, hdv, causal):
    key = jax.random.PRNGKey(s + t)
    q = jax.random.normal(key, (b, s, kh, g, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kh, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kh, hdv))
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_pallas_flash_matches_model_flash():
    """Kernel vs the jnp flash used by the models (two independent paths)."""
    from repro.models.lm.flash import flash_attention as jnp_flash

    key = jax.random.PRNGKey(0)
    b, s, kh, g, hd = 1, 128, 2, 2, 32
    q = jax.random.normal(key, (b, s, kh, g, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, hd))
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = jnp_flash(q, k, v, True, 64, 64)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_pallas_flash_bf16_inputs():
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 128, 1, 2, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 1, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 1, 32), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = flash_attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=3e-2, atol=3e-2
    )
